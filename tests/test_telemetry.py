"""Telemetry layer: registry semantics (labels, windowed percentiles,
Prometheus exposition, the jax-value rejection that enforces the
zero-host-sync contract), RequestLog ring + per-rid queries, XPUTimer
thread safety and memory accounting, SLOTracker gating, Chrome-trace
structural validity from a real engine run, the /metrics HTTP endpoint,
and the instrumented engine's compile/transfer contract under churn."""
import json
import threading
import urllib.request

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.analysis.contracts import compile_guard, transfer_guard
from repro.configs.base import get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.serving.online import OnlineConfig, OnlineEngine, OnlineRequest
from repro.telemetry import (
    EVENTS, MetricsRegistry, MetricsServer, RequestLog, SLOConfig,
    SLOTracker, XPUTimer, chrome_trace, write_chrome_trace,
)
from repro.telemetry.metrics import DEFAULT_MS_BUCKETS, Histogram, Series
from repro.telemetry.xputimer import FULL_RECORD_BYTES


@pytest.fixture(scope="module")
def runner_params():
    cfg = get_smoke_config("ling-lite")
    runner = api.Runner(cfg, make_local_mesh(1, 1), fsdp=False,
                        seq_parallel=False, max_seq=64)
    return runner, runner.init_params(0)


def churn_engine(runner, params, **cfg_kw):
    """13-request ragged run through a 4-slot pool sized to preempt."""
    ocfg = OnlineConfig(max_slots=4, max_context=32, page_size=8,
                        n_pages=7, prefill_chunk=4, **cfg_kw)
    eng = OnlineEngine(runner, params, ocfg)
    rs = np.random.RandomState(1)
    reqs = [OnlineRequest(
                rid=i,
                prompt=rs.randint(0, runner.cfg.vocab_size,
                                  4 + (i % 5)).astype(np.int32),
                max_new=8 + (i % 9))
            for i in range(13)]
    eng.submit_many(reqs)
    eng.run(max_ticks=3000)
    return eng, reqs


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------


def test_counter_gauge_basics_and_labels():
    reg = MetricsRegistry()
    c = reg.counter("serve_shed_total", "sheds", reason="slo")
    c.inc()
    c.inc(2)
    # same labels -> same child; different labels -> sibling
    assert reg.counter("serve_shed_total", reason="slo") is c
    other = reg.counter("serve_shed_total", reason="queue")
    assert other is not c and other.value == 0
    assert c.value == 3
    with pytest.raises(ValueError):
        c.inc(-1)                      # counters only go up
    g = reg.gauge("queue_depth")
    g.set(4)
    g.add(-1)
    assert g.value == 3
    with pytest.raises(ValueError):
        reg.gauge("serve_shed_total")  # kind mismatch on one name


def test_registry_rejects_jax_values():
    """The zero-host-sync contract is structural: device values (which
    carry .aval) raise before any float() could sync."""
    reg = MetricsRegistry()
    x = jnp.float32(1.5)
    with pytest.raises(TypeError, match="host-side scalars only"):
        reg.counter("c").inc(x)
    with pytest.raises(TypeError, match="host-side scalars only"):
        reg.gauge("g").set(x)
    with pytest.raises(TypeError, match="host-side scalars only"):
        reg.histogram("h").observe(x)
    with pytest.raises(TypeError, match="host-side scalars only"):
        reg.series("s").sample(x, t_us=0)
    # numpy scalars are host data and pass
    reg.counter("c").inc(np.float64(2.0))
    assert reg.counter("c").value == 2.0


def test_histogram_buckets_and_windowed_percentiles():
    h = Histogram(buckets=(1.0, 10.0, 100.0), window=4)
    for v in (0.5, 5.0, 50.0, 500.0):
        h.observe(v)
    assert h.cumulative() == [(1.0, 1), (10.0, 2), (100.0, 3),
                              (float("inf"), 4)]
    assert h.count == 4 and h.sum == pytest.approx(555.5)
    # window holds the last 4: push 4 more and the percentile view moves
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.window_count() == 4
    assert h.percentile(0) == 1.0
    assert h.percentile(100) == 4.0
    assert h.percentile(50) == pytest.approx(2.5)
    # cumulative buckets still cover the lifetime distribution
    assert h.cumulative()[-1] == (float("inf"), 8)


def test_series_ring_wraps_chronologically():
    s = Series("queue_depth", capacity=4)
    for i in range(6):
        s.sample(float(i), t_us=100 + i)
    assert len(s) == 4
    assert s.points() == [(102, 2.0), (103, 3.0), (104, 4.0), (105, 5.0)]


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("serve_enqueued_total", "requests accepted").inc(7)
    reg.histogram("serve_ttft_ms", "ttft", buckets=(1.0, 10.0)).observe(5.0)
    reg.series("page_pool_occupancy").sample(3, t_us=1)  # not exposed
    text = reg.render_prometheus()
    assert "# TYPE serve_enqueued_total counter" in text
    assert "serve_enqueued_total 7" in text
    assert "# HELP serve_enqueued_total requests accepted" in text
    assert 'serve_ttft_ms_bucket{le="1"} 0' in text
    assert 'serve_ttft_ms_bucket{le="10"} 1' in text
    assert 'serve_ttft_ms_bucket{le="+Inf"} 1' in text
    assert "serve_ttft_ms_sum 5" in text
    assert "serve_ttft_ms_count 1" in text
    assert "page_pool_occupancy" not in text
    # cumulative bucket counts are monotone for every histogram family
    reg.histogram("serve_ttft_ms", buckets=(1.0, 10.0)).observe(0.5)
    cum = reg.histogram("serve_ttft_ms", buckets=(1.0, 10.0)).cumulative()
    assert [c for _, c in cum] == sorted(c for _, c in cum)


# ---------------------------------------------------------------------------
# RequestLog
# ---------------------------------------------------------------------------


def test_request_log_lifecycle_and_per_rid_query():
    rlog = RequestLog(ring_size=64)
    rlog.record("enqueue", rid=7, tick=0, t_us=10)
    rlog.record("admit", rid=7, slot=2, tick=1, arg=5, t_us=20)
    rlog.record("enqueue", rid=8, tick=1, t_us=25)
    rlog.record("complete", rid=7, slot=2, tick=9, arg=4, t_us=90)
    assert rlog.counts() == {"enqueue": 2, "admit": 1, "complete": 1}
    evs = rlog.events_for(7)
    assert [e["event"] for e in evs] == ["enqueue", "admit", "complete"]
    assert evs[1]["slot"] == 2 and evs[1]["arg"] == 5
    assert rlog.events_for(99) == []
    with pytest.raises(KeyError):
        rlog.record("not_an_event", rid=0)   # typos fail loudly


def test_request_log_ring_wraps_chronologically():
    rlog = RequestLog(ring_size=8)
    for i in range(20):
        rlog.record("decode", rid=i, t_us=1000 + i)
    assert rlog.n_records == 8
    recs = rlog.records()
    assert list(recs["rid"]) == list(range(12, 20))
    assert list(recs["t_us"]) == [1012 + i for i in range(8)]
    assert rlog.memory_bytes() == 8 * recs.itemsize


# ---------------------------------------------------------------------------
# XPUTimer (thread-safety + memory-accounting satellites)
# ---------------------------------------------------------------------------


def test_xputimer_span_two_thread_hammer():
    """Spans closing concurrently on two threads race the span registry,
    the SpanStats deques and the ring head unless the whole close path
    sits under the lock — counts must come out exact."""
    timer = XPUTimer(ring_size=1 << 14)
    N = 2000
    errs = []

    def hammer(name):
        try:
            for _ in range(N):
                with timer.span(name):
                    pass
                with timer.span("shared"):
                    pass
        except Exception as e:       # pragma: no cover - failure path
            errs.append(e)

    ts = [threading.Thread(target=hammer, args=(f"t{i}",)) for i in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
    assert timer.stats["shared"].count == 2 * N
    assert timer.stats["t0"].count == N and timer.stats["t1"].count == N
    assert timer.n_records == 4 * N
    names = timer.span_names()
    assert len(names) == len(set(names)) == 3   # no duplicate sids


def test_xputimer_memory_accounting_shares_record_count():
    """full_tracing_bytes and memory_bytes derive from the same
    n_records (the old code branched on wrapped twice and could
    disagree); the Fig.4 ratio stays ~10x regardless of wrap."""
    timer = XPUTimer(ring_size=16)
    for _ in range(40):              # wraps the ring 2.5x
        with timer.span("s"):
            pass
    assert timer.n_records == 16
    assert timer.full_tracing_bytes() == 16 * FULL_RECORD_BYTES
    assert timer.memory_bytes() == 16 * timer.ring.itemsize + 64
    assert timer.full_tracing_bytes() / timer.memory_bytes() > 5.0


def test_xputimer_publishes_into_registry():
    reg = MetricsRegistry()
    timer = XPUTimer(registry=reg)
    with timer.span("tick"):
        pass
    timer.count("commits", 3)
    timer.gauge("commit_frac", 0.5)
    h = reg.get("xputimer_span_ms", span="tick")
    assert h is not None and h.count == 1
    assert reg.get("xputimer_counter_total", counter="commits").value == 3
    assert reg.get("xputimer_gauge", gauge="commit_frac").value == 0.5


# ---------------------------------------------------------------------------
# SLOTracker
# ---------------------------------------------------------------------------


def test_slo_config_validation():
    with pytest.raises(ValueError):
        SLOConfig(ttft_p99_ms=0)
    with pytest.raises(ValueError):
        SLOConfig(ttft_p99_ms=10, itl_p99_ms=-1)
    with pytest.raises(ValueError):
        SLOConfig(ttft_p99_ms=10, headroom=0)


def test_slo_tracker_gate_arms_after_min_observations():
    reg = MetricsRegistry()
    slo = SLOTracker(SLOConfig(ttft_p99_ms=100.0, min_observations=4,
                               window=16), reg)
    # cold: never sheds regardless of load
    assert slo.should_shed(queued_prompt_tokens=10_000,
                           prefill_chunk=8) is None
    for _ in range(4):
        slo.observe_tick(10.0)       # tick p50 = 10ms
    # 80 queued tokens / chunk 8 = 10 ticks -> 100ms estimate: borderline
    assert slo.should_shed(80, 8) is None
    # 800 tokens -> 1000ms estimate > 100ms deadline
    reason = slo.should_shed(800, 8)
    assert reason is not None and "ttft_estimate" in reason
    # backward signal: observed window p99 breaches
    for _ in range(4):
        slo.observe_ttft(500.0)
    reason = slo.should_shed(8, 8)
    assert reason is not None and "ttft_p99" in reason
    slo.on_shed()
    snap = slo.snapshot()
    assert snap["shed"] == 1 and snap["ttft_deadline_ms"] == 100.0


def test_slo_tracker_itl_deadline():
    reg = MetricsRegistry()
    slo = SLOTracker(SLOConfig(ttft_p99_ms=1e6, itl_p99_ms=5.0,
                               min_observations=2, window=8), reg)
    for _ in range(2):
        slo.observe_tick(0.1)
        slo.observe_itl(50.0)
    reason = slo.should_shed(1, 8)
    assert reason is not None and "itl_p99" in reason


# ---------------------------------------------------------------------------
# engine integration: metrics + lifecycle log + contracts under churn
# ---------------------------------------------------------------------------


def test_engine_telemetry_under_churn_keeps_contracts(runner_params):
    """The fully instrumented engine (registry + request log + timer on)
    still compiles exactly one prefill and one decode step and performs
    no implicit device->host transfer in the tick loop."""
    runner, params = runner_params
    ocfg = OnlineConfig(max_slots=4, max_context=32, page_size=8,
                        n_pages=7, prefill_chunk=4)
    eng = OnlineEngine(runner, params, ocfg)
    rs = np.random.RandomState(1)
    reqs = [OnlineRequest(
                rid=i,
                prompt=rs.randint(0, runner.cfg.vocab_size,
                                  4 + (i % 5)).astype(np.int32),
                max_new=8 + (i % 9))
            for i in range(13)]
    eng.submit_many(reqs)
    with compile_guard({"prefill": 1, "decode": 1}, eng.compiles,
                       exact=True), transfer_guard("disallow"):
        eng.run(max_ticks=3000)
    assert all(r.done for r in reqs)
    assert eng.n_preemptions > 0

    # lifecycle ledger is complete and consistent
    counts = eng.rlog.counts()
    assert counts["enqueue"] == 13
    assert counts["complete"] == 13
    assert counts["first_token"] == 13
    # preemption mid-prefill re-admits without a prefill_done, so the
    # count sits between one-per-request and one-per-admit
    assert 13 <= counts["prefill_done"] <= counts["admit"]
    assert counts["admit"] == 13 + counts["requeue"]
    assert counts["preempt"] == counts["requeue"] == eng.n_preemptions
    assert counts.get("evict", 0) == eng.alloc.stats["evictions"]
    # per-rid trail starts at enqueue and ends at complete
    for rid in (0, 7, 12):
        evs = [e["event"] for e in eng.rlog.events_for(rid)]
        assert evs[0] == "enqueue" and evs[-1] == "complete"
        assert "first_token" in evs

    # registry mirrors the ledger
    reg = eng.registry
    assert reg.get("serve_enqueued_total").value == 13
    assert reg.get("serve_completed_total").value == 13
    assert reg.get("serve_preemptions_total").value == eng.n_preemptions
    assert reg.get("serve_cache_evictions_total").value \
        == eng.alloc.stats["evictions"]
    assert reg.get("serve_tokens_total").value \
        == sum(len(r.out) for r in reqs)
    assert reg.get("serve_ttft_ms").count == 13
    assert reg.get("serve_ttft_ms").percentile(99) > 0
    assert reg.get("serve_tick_ms").count == eng.ticks
    assert reg.get("serve_itl_ms").count > 0
    # timer phases landed in the shared registry too
    assert reg.get("xputimer_span_ms", span="tick").count == eng.ticks
    # counter tracks sampled every tick
    assert len(reg.series("queue_depth")) == eng.ticks
    assert len(reg.series("page_pool_occupancy")) == eng.ticks
    occ = [v for _, v in reg.series("page_pool_occupancy").points()]
    assert max(occ) <= eng.alloc.n_pages


def test_engine_slo_gate_sheds_under_pressure(runner_params):
    """overload="slo" with an unmeetable TTFT deadline: warm requests
    arm the gate, then a flood is shed while already-admitted work
    completes; sheds are visible in state, metrics and the request log."""
    runner, params = runner_params
    slo = SLOConfig(ttft_p99_ms=0.05, min_observations=2, window=16)
    eng, reqs = None, None
    ocfg = OnlineConfig(max_slots=2, max_context=32, page_size=8,
                        prefill_chunk=4, overload="slo", slo=slo)
    eng = OnlineEngine(runner, params, ocfg)
    rs = np.random.RandomState(0)

    def req(rid):
        return OnlineRequest(rid=rid,
                             prompt=rs.randint(0, runner.cfg.vocab_size,
                                               8).astype(np.int32),
                             max_new=4)

    warm = [req(i) for i in range(2)]
    for r in warm:
        assert eng.submit(r)         # cold gate admits freely
    eng.run(max_ticks=500)           # warms the tick window (>= 2 obs)
    flood = [req(100 + i) for i in range(4)]
    admitted = [eng.submit(r) for r in flood]
    assert not any(admitted), "armed gate must shed past the knee"
    assert all(r.state == "shed" for r in flood)
    assert eng.n_shed == 4
    assert eng.registry.get("serve_shed_total").value == 4
    assert eng.registry.get("serve_slo_shed_total").value == 4
    assert eng.rlog.counts()["shed"] == 4
    assert all(r.done for r in warm)


def test_engine_rejects_slo_overload_without_config(runner_params):
    runner, params = runner_params
    with pytest.raises(ValueError, match="slo"):
        OnlineEngine(runner, params,
                     OnlineConfig(max_slots=2, max_context=32,
                                  overload="slo"))


# ---------------------------------------------------------------------------
# trace export (acceptance criterion: structurally valid Perfetto JSON)
# ---------------------------------------------------------------------------


def test_chrome_trace_from_real_run(runner_params, tmp_path):
    runner, params = runner_params
    eng, reqs = churn_engine(runner, params)
    path = tmp_path / "trace.json"
    n = write_chrome_trace(path, timer=eng.timer, request_log=eng.rlog,
                           registry=eng.registry)
    trace = json.loads(path.read_text())
    assert trace["displayTimeUnit"] == "ms"
    events = trace["traceEvents"]
    assert len(events) == n > 0

    for e in events:
        assert e["ph"] in ("X", "i", "C", "M")
        assert isinstance(e["ts"], int) and e["ts"] >= 0
        if e["ph"] == "X":
            assert e["dur"] >= 1
        if e["ph"] != "M":
            assert e["pid"] in (1, 2, 3)

    names = {e["name"] for e in events}
    # scheduler-phase tracks from the timer ring
    meta_names = {e["args"]["name"] for e in events if e["ph"] == "M"}
    assert {"tick", "prefill", "decode", "admit"} <= meta_names
    # per-slot prefill/decode spans with rids
    x_names = {e["name"] for e in events if e["ph"] == "X" and e["pid"] == 2}
    assert any(s.startswith("prefill r") for s in x_names)
    assert any(s.startswith("decode r") for s in x_names)
    # instants for the churn (preempts were forced by the page pool)
    assert any(e["ph"] == "i" and e["name"].startswith("preempt r")
               for e in events)
    assert any(e["ph"] == "i" and e["name"].startswith("first_token r")
               for e in events)
    # counter tracks from the registry series
    c_names = {e["name"] for e in events if e["ph"] == "C"}
    assert {"page_pool_occupancy", "queue_depth", "radix_hit_rate"} \
        <= c_names
    assert "engine slots" in {e["args"]["name"] for e in events
                              if e["ph"] == "M"}, names
    # timestamps were rebased near zero
    assert min(e["ts"] for e in events if e["ph"] != "M") == 0


def test_chrome_trace_sources_optional():
    reg = MetricsRegistry()
    reg.series("queue_depth").sample(1, t_us=5)
    trace = chrome_trace(registry=reg)
    assert any(e["ph"] == "C" for e in trace["traceEvents"])
    assert chrome_trace()["traceEvents"] == []


# ---------------------------------------------------------------------------
# Prometheus endpoint
# ---------------------------------------------------------------------------


def test_metrics_server_serves_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("serve_enqueued_total", "requests").inc(5)
    reg.histogram("serve_ttft_ms", "ttft").observe(12.0)
    with MetricsServer(reg, port=0) as srv:
        url = f"http://127.0.0.1:{srv.port}/metrics"
        body = urllib.request.urlopen(url, timeout=10).read().decode()
        assert "serve_enqueued_total 5" in body
        assert "serve_ttft_ms_count 1" in body
        # live view: scrape again after more traffic
        reg.counter("serve_enqueued_total").inc()
        body = urllib.request.urlopen(url, timeout=10).read().decode()
        assert "serve_enqueued_total 6" in body
        with pytest.raises(Exception):
            urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/nope", timeout=10)
