"""Self-draft speculative decoding: greedy streams token-exact versus
non-speculative decode (for ANY drafter — acceptance only changes
speed), compile counts pinned at one prefill + one draft + one verify
under churn, full-depth self-draft hitting 100% acceptance with
ticks-per-token ~ 1/(k+1), page-table trim/rewind bookkeeping, and the
pluggable small-config drafter path."""
import dataclasses

import numpy as np
import pytest

from repro import api
from repro.configs.base import get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.serving.draft import (ConfigDrafter, SelfDrafter,
                                 adapt_drafter_config)
from repro.serving.online import OnlineConfig, OnlineEngine, OnlineRequest
from repro.serving.segment_cache import PageAllocator


@pytest.fixture(scope="module")
def runner_params():
    cfg = get_smoke_config("ling-lite")
    runner = api.Runner(cfg, make_local_mesh(1, 1), fsdp=False,
                        seq_parallel=False, max_seq=64)
    return runner, runner.init_params(0)


def _greedy_ref(runner, params, prompts, max_new):
    eng = OnlineEngine(runner, params, OnlineConfig(
        max_slots=len(prompts), max_context=64, page_size=16,
        prefill_chunk=4))
    eng.submit_many([OnlineRequest(rid=i, prompt=prompts[i],
                                   max_new=max_new)
                     for i in range(len(prompts))])
    eng.run(max_ticks=1000)
    return [list(eng.reqs[i].out) for i in range(len(prompts))]


def _spec_engine(runner, params, *, spec_k=2, draft_layers=1, **kw):
    ocfg = OnlineConfig(max_slots=kw.pop("max_slots", 4),
                        max_context=kw.pop("max_context", 64),
                        page_size=kw.pop("page_size", 16),
                        prefill_chunk=kw.pop("prefill_chunk", 4),
                        spec_k=spec_k, **kw)
    return OnlineEngine(runner, params, ocfg,
                        drafter=SelfDrafter(draft_layers=draft_layers))


def test_spec_greedy_token_exact_and_compile_counts(runner_params):
    """A truncated 1-layer drafter proposes imperfectly, yet the greedy
    spec stream is bitwise the non-spec greedy stream — rejected drafts
    are replaced by the target's own argmax.  Exactly one prefill + one
    draft + one verify compile; the plain decode step never traces."""
    runner, params = runner_params
    B, P, NEW = 4, 6, 6
    rs = np.random.RandomState(0)
    prompts = [rs.randint(0, runner.cfg.vocab_size, P).astype(np.int32)
               for _ in range(B)]
    ref = _greedy_ref(runner, params, prompts, NEW)

    eng = _spec_engine(runner, params, spec_k=2, draft_layers=1)
    eng.submit_many([OnlineRequest(rid=i, prompt=prompts[i], max_new=NEW)
                     for i in range(B)])
    eng.run(max_ticks=1000)
    out = [list(eng.reqs[i].out) for i in range(B)]
    assert out == ref
    assert eng.prefill_traces == 1
    assert eng.draft_traces == 1
    assert eng.verify_traces == 1
    assert eng.decode_traces == 0
    assert eng.spec_proposed > 0


def test_spec_full_depth_accepts_everything(runner_params):
    """draft_layers == n_layers makes the drafter an exact copy of the
    target (q == p bitwise): every draft accepted, each tick commits
    k+1 tokens, so decode ticks per emitted token ~ 1/(k+1) < 0.7."""
    runner, params = runner_params
    K, B, NEW = 2, 4, 9
    rs = np.random.RandomState(1)
    prompts = [rs.randint(0, runner.cfg.vocab_size, 6).astype(np.int32)
               for _ in range(B)]
    ref = _greedy_ref(runner, params, prompts, NEW)

    eng = _spec_engine(runner, params, spec_k=K,
                       draft_layers=runner.cfg.n_layers)
    eng.submit_many([OnlineRequest(rid=i, prompt=prompts[i], max_new=NEW)
                     for i in range(B)])
    eng.run(max_ticks=1000)
    assert [list(eng.reqs[i].out) for i in range(B)] == ref
    assert eng.spec_accepted == eng.spec_proposed   # 100% acceptance
    ticks = sum(eng.reqs[i].n_decode_ticks for i in range(B))
    decoded = sum(len(eng.reqs[i].out) - 1 for i in range(B))
    assert ticks / decoded < 0.7, (ticks, decoded)


def test_spec_compile_counts_under_churn(runner_params):
    """>= 3x max_slots ragged requests through a pool sized to force
    preemption, with speculative decoding on: every request completes,
    trims happen, pages never leak, compile counts stay 1/1/1, and the
    run is deterministic (trim's LIFO page recycling keeps page tables
    identical across reruns)."""
    runner, params = runner_params

    def drive():
        eng = _spec_engine(runner, params, spec_k=2, draft_layers=1,
                           max_slots=4, max_context=32, page_size=8,
                           n_pages=9, prefill_chunk=4)
        rs = np.random.RandomState(2)
        reqs = [OnlineRequest(
                    rid=i,
                    prompt=rs.randint(0, runner.cfg.vocab_size,
                                      4 + (i % 5)).astype(np.int32),
                    max_new=8 + (i % 9))
                for i in range(13)]                  # > 3 * max_slots
        eng.submit_many(reqs)
        eng.run(max_ticks=3000)
        return eng, reqs

    eng, reqs = drive()
    assert eng.prefill_traces == 1
    assert eng.draft_traces == 1
    assert eng.verify_traces == 1
    assert eng.n_preemptions > 0, "pool was sized to force preemption"
    assert eng.alloc.stats["trims"] > 0, "rejections must rewind pages"
    for r in reqs:
        assert r.done and len(r.out) == r.max_new, (r.rid, r.state)
    eng.alloc.check_invariants()
    # released pages are *published* into the radix cache, not freed;
    # flushing the cache must hand every page back to the pool
    eng.alloc.flush_radix()
    eng.alloc.check_invariants()
    assert eng.alloc.n_free == eng.alloc.n_pages - eng.alloc.reserved

    eng2, reqs2 = drive()
    assert eng2.admission_log == eng.admission_log
    assert eng2.n_preemptions == eng.n_preemptions
    for a, b in zip(reqs, reqs2):
        assert a.out == b.out, (a.rid, a.out, b.out)

    # greedy exactness survives the preemption/trim churn
    ref_eng = OnlineEngine(runner, params, OnlineConfig(
        max_slots=4, max_context=32, page_size=8, prefill_chunk=4))
    rs = np.random.RandomState(2)
    refs = [OnlineRequest(
                rid=i,
                prompt=rs.randint(0, runner.cfg.vocab_size,
                                  4 + (i % 5)).astype(np.int32),
                max_new=8 + (i % 9))
            for i in range(13)]
    ref_eng.submit_many(refs)
    ref_eng.run(max_ticks=3000)
    for a, b in zip(reqs, refs):
        assert a.out == b.out, (a.rid, a.out, b.out)


def test_spec_nonzero_temperature(runner_params):
    """Stochastic spec decoding: with a full-depth drafter q == p, the
    accept rule u*q < p accepts every draft; streams are reproducible
    for a fixed seed and all tokens stay in-vocab."""
    runner, params = runner_params
    rs = np.random.RandomState(3)
    prompts = [rs.randint(0, runner.cfg.vocab_size, 6).astype(np.int32)
               for _ in range(2)]

    def drive():
        eng = _spec_engine(runner, params, spec_k=2,
                           draft_layers=runner.cfg.n_layers, max_slots=2,
                           temperature=1.2, seed=42)
        eng.submit_many([OnlineRequest(rid=i, prompt=prompts[i],
                                       max_new=8)
                         for i in range(2)])
        eng.run(max_ticks=1000)
        return [list(eng.reqs[i].out) for i in range(2)], eng

    out, eng = drive()
    assert eng.spec_accepted == eng.spec_proposed
    assert all(0 <= t < runner.cfg.vocab_size for o in out for t in o)
    out2, _ = drive()
    assert out == out2

    # a truncated drafter under the same temperature still completes,
    # with acceptance strictly between forced extremes
    eng3 = _spec_engine(runner, params, spec_k=2, draft_layers=1,
                        max_slots=2, temperature=1.2, seed=42)
    eng3.submit_many([OnlineRequest(rid=i, prompt=prompts[i], max_new=8)
                      for i in range(2)])
    eng3.run(max_ticks=1000)
    assert all(len(eng3.reqs[i].out) == 8 for i in range(2))


def test_config_drafter_pluggable(runner_params):
    """A foreign small config (adapted h2o-danube smoke: swa blocks
    rewritten to attn, vocab aligned) rides the same drafter interface
    with randomly initialized weights — rarely accepted, but the greedy
    stream stays token-exact because rejections fall back to the
    target's argmax."""
    runner, params = runner_params
    B, NEW = 2, 6
    rs = np.random.RandomState(4)
    prompts = [rs.randint(0, runner.cfg.vocab_size, 6).astype(np.int32)
               for _ in range(B)]
    ref = _greedy_ref(runner, params, prompts, NEW)

    dcfg = adapt_drafter_config(get_smoke_config("h2o-danube-1.8b"),
                                runner.cfg)
    assert dcfg.vocab_size == runner.cfg.vocab_size
    eng = OnlineEngine(
        runner, params,
        OnlineConfig(max_slots=B, max_context=64, page_size=16,
                     prefill_chunk=4, spec_k=2),
        drafter=ConfigDrafter(dcfg))
    eng.submit_many([OnlineRequest(rid=i, prompt=prompts[i], max_new=NEW)
                     for i in range(B)])
    eng.run(max_ticks=1000)
    assert [list(eng.reqs[i].out) for i in range(B)] == ref


def test_spec_requires_drafter(runner_params):
    runner, params = runner_params
    with pytest.raises(ValueError, match="drafter"):
        OnlineEngine(runner, params,
                     OnlineConfig(max_slots=2, max_context=32, spec_k=2))


def test_drafter_layer_bounds(runner_params):
    runner, params = runner_params
    with pytest.raises(ValueError, match="draft_layers"):
        SelfDrafter(draft_layers=0).build(runner, params)
    with pytest.raises(ValueError, match="draft_layers"):
        SelfDrafter(draft_layers=runner.cfg.n_layers + 1).build(runner,
                                                                params)


def test_config_drafter_vocab_guard(runner_params):
    runner, params = runner_params
    bad = dataclasses.replace(runner.cfg,
                              vocab_size=runner.cfg.vocab_size + 64)
    with pytest.raises(ValueError, match="vocab_size"):
        ConfigDrafter(bad).build(runner, params)


def test_page_allocator_trim():
    """trim rewinds the table tail LIFO so an immediate regrow
    reacquires the identical pages; shared-prefix pages never trim."""
    alloc = PageAllocator(n_pages=10, page_size=4)
    alloc.admit(0)
    assert alloc.ensure_capacity(0, 16)            # 4 pages
    held = list(alloc.pages[0])
    alloc.trim(0, 6)                               # keep 2 pages
    assert alloc.pages[0] == held[:2]
    assert alloc.stats["trims"] == 2
    assert alloc.ensure_capacity(0, 16)
    assert alloc.pages[0] == held                  # LIFO regrow: same ids
    alloc.check_invariants()

    # published prefix pages survive a trim below their extent
    alloc.register_prefix(0, "sys", 8)             # first 2 pages shared
    alloc.trim(0, 0)
    assert alloc.pages[0] == held[:2]
    alloc.release(0)
    alloc.drop_prefix("sys")
    alloc.check_invariants()
    assert alloc.n_free == alloc.n_pages - alloc.reserved
