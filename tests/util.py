"""Shared test helpers."""
import jax
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_local_mesh
from repro.sharding import make_axis_env


def smap_env(fn, *, out_specs=None):
    """Run a model-internal function under a 1x1 shard_map so axis names
    exist.  fn(env, *args); all args/outputs replicated."""
    mesh = make_local_mesh(1, 1)
    env = make_axis_env(mesh)

    def call(*args):
        wrapped = jax.shard_map(
            lambda *a: fn(env, *a), mesh=mesh,
            in_specs=tuple(P() for _ in args),
            out_specs=out_specs if out_specs is not None else P(),
            check_vma=False)
        return wrapped(*args)

    return call, env
