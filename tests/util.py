"""Shared test helpers."""
import jax
from jax.sharding import PartitionSpec as P

# Optional-dep shim: `hypothesis` is not installed in every container.
# Property tests import given/settings/st from here; without hypothesis
# they collect as skipped instead of crashing the whole run.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    import pytest as _pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return _pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """st.integers(...)/st.lists(...) evaluate at collection time;
        the skipped test never calls the result."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro import sharding
from repro.launch.mesh import make_local_mesh
from repro.sharding import make_axis_env


def smap_env(fn, *, out_specs=None):
    """Run a model-internal function under a 1x1 shard_map so axis names
    exist.  fn(env, *args); all args/outputs replicated."""
    mesh = make_local_mesh(1, 1)
    env = make_axis_env(mesh)

    def call(*args):
        wrapped = sharding.shard_map(
            lambda *a: fn(env, *a), mesh=mesh,
            in_specs=tuple(P() for _ in args),
            out_specs=out_specs if out_specs is not None else P())
        return wrapped(*args)

    return call, env
