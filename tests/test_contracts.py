"""Runtime contract layer (analysis/contracts.py): compile counting +
guards, donation verification, transfer-guard wiring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.contracts import (
    CompileCounter, CompileGuardError, DonationError,
    compile_guard, donation_check, env_debug_guards, transfer_guard,
)


def test_compile_counter_counts_traces_not_calls():
    c = CompileCounter()
    f = c.jit("f", lambda x: x * 2)
    for _ in range(5):
        f(jnp.ones((4,)))
    assert c["f"] == 1            # one shape -> one trace
    f(jnp.ones((8,)))             # new shape -> one more trace
    assert c["f"] == 2
    assert c.total() == 2
    assert c.snapshot() == {"f": 2}


def test_compile_guard_total_and_per_label():
    c = CompileCounter()
    f = c.jit("f", lambda x: x + 1)
    g = c.jit("g", lambda x: x - 1)
    with compile_guard(2, c):
        f(jnp.ones(3))
        g(jnp.ones(3))
    with compile_guard({"f": 0}, c):        # already compiled: no retrace
        f(jnp.ones(3))
    with pytest.raises(CompileGuardError, match="expected <=0"):
        with compile_guard({"f": 0}, c):
            f(jnp.ones(7))                  # fresh shape retraces


def test_compile_guard_exact():
    c = CompileCounter()
    f = c.jit("f", lambda x: x)
    with pytest.raises(CompileGuardError, match="expected ==1"):
        with compile_guard({"f": 1}, c, exact=True):
            pass                            # zero traces != exactly one
    with compile_guard({"f": 1}, c, exact=True):
        f(jnp.ones(2))


def test_compile_guard_unconstrained_labels_free():
    c = CompileCounter()
    f = c.jit("f", lambda x: x)
    g = c.jit("g", lambda x: x)
    with compile_guard({"f": 1}, c):
        f(jnp.ones(2))
        g(jnp.ones(2))                      # g not limited


def test_donation_check_passes_on_donating_jit():
    f = jax.jit(lambda p, b: p + b, donate_argnums=(0,))
    p = jnp.ones((8,))
    out = donation_check(f, (0,), p, jnp.ones((8,)))
    np.testing.assert_allclose(np.asarray(out), 2.0)


def test_donation_check_raises_when_donation_dropped():
    f = jax.jit(lambda p, b: p + b)         # no donate_argnums
    with pytest.raises(DonationError, match="live leaf"):
        donation_check(f, (0,), jnp.ones((8,)), jnp.ones((8,)))


def test_transfer_guard_smoke():
    # CPU backend never fires transfer guards (host==device memory), so
    # this is structural: the wrapper must nest cleanly around jitted
    # work and explicit device_get on any backend
    f = jax.jit(lambda x: x * 3)
    with transfer_guard("disallow"):
        y = f(jnp.ones((4,)))
        host = jax.device_get(y)            # explicit: always legal
    np.testing.assert_allclose(host, 3.0)


def test_env_debug_guards(monkeypatch):
    monkeypatch.delenv("REPRO_DEBUG_GUARDS", raising=False)
    assert env_debug_guards() is False
    assert env_debug_guards(default=True) is True
    monkeypatch.setenv("REPRO_DEBUG_GUARDS", "1")
    assert env_debug_guards() is True
    monkeypatch.setenv("REPRO_DEBUG_GUARDS", "off")
    assert env_debug_guards() is False


def test_trainer_and_engine_expose_debug_guards():
    # config plumbing only (engine construction is covered elsewhere):
    # None defers to the env var at construction time
    from repro.serving.online import OnlineConfig
    from repro.training.trainer import TrainConfig
    assert OnlineConfig(max_slots=2, max_context=32).debug_guards is None
    assert TrainConfig().debug_guards is None
