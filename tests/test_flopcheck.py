"""flopcheck rule tests: exact (line, rule) matches over the fixture
corpus, suppression semantics, the cross-file registry, the historical
regression snippets the tool exists for, and a clean-tree gate."""
import re
import textwrap
from pathlib import Path

import pytest

from repro.analysis import RULES, check_paths, check_source
from repro.analysis.flopcheck import (
    build_registry, check_file, iter_py_files,
)

HERE = Path(__file__).resolve().parent
ROOT = HERE.parent
CORPUS = HERE / "flopcheck_corpus"
EXPECT_RE = re.compile(r"#\s*EXPECT:\s*(FC-[A-Z]+)")
CORPUS_FILES = sorted(CORPUS.glob("fc_*.py"))


def expected_marks(path: Path):
    out = set()
    for i, line in enumerate(path.read_text().splitlines(), 1):
        m = EXPECT_RE.search(line)
        if m:
            out.add((i, m.group(1)))
    return out


# ---------------------------------------------------------------------------
# corpus: exact line + rule-ID matches, positives and negatives together
# ---------------------------------------------------------------------------


def test_corpus_covers_every_rule():
    marked = {r for p in CORPUS_FILES for _, r in expected_marks(p)}
    assert marked == set(RULES), (
        f"corpus is missing positive fixtures for "
        f"{set(RULES) - marked or set()}")


@pytest.mark.parametrize("path", CORPUS_FILES, ids=lambda p: p.stem)
def test_corpus_exact_lines(path):
    got = {(v.line, v.rule) for v in check_file(path) if not v.suppressed}
    assert got == expected_marks(path)


# ---------------------------------------------------------------------------
# suppression syntax
# ---------------------------------------------------------------------------


def test_suppressions_inline_standalone_and_multi_rule():
    vs = check_file(CORPUS / "suppressions.py")
    assert vs, "fixtures should still be detected"
    assert all(v.suppressed for v in vs), \
        [v.format() for v in vs if not v.suppressed]
    # both comment placements worked
    assert sum(v.rule == "FC-HOSTSYNC" for v in vs) >= 2
    assert any(v.rule == "FC-RECOMPILE" for v in vs)


def test_disable_file_suppresses_everywhere():
    src = textwrap.dedent("""
        # flopcheck: disable-file=FC-DEPRECATED
        import jax

        def f(fn, tree):
            return jax.tree_map(fn, tree)

        def g(fn, tree):
            return jax.tree_map(fn, tree)
    """)
    vs = check_source(src)
    assert len(vs) == 2 and all(v.suppressed for v in vs)


def test_unsuppressed_rule_still_fires_next_to_suppressed_one():
    src = textwrap.dedent("""
        import jax

        def f(fn, tree):
            a = jax.tree_map(fn, tree)  # flopcheck: disable=FC-DEPRECATED
            b = jax.tree_map(fn, tree)
            return a, b
    """)
    vs = check_source(src)
    assert [v.suppressed for v in sorted(vs, key=lambda v: v.line)] \
        == [True, False]


# ---------------------------------------------------------------------------
# cross-file registry: static-arg'd jit in one file, call site in another
# ---------------------------------------------------------------------------


def test_cross_file_static_argnames(tmp_path):
    (tmp_path / "kernels.py").write_text(textwrap.dedent("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("bm",))
        def tiled(x, bm):
            return x
    """))
    (tmp_path / "caller.py").write_text(textwrap.dedent("""
        from kernels import tiled

        def run(x):
            return tiled(x, bm=[8, 8])
    """))
    vs = [v for v in check_paths([tmp_path]) if not v.suppressed]
    assert [(Path(v.path).name, v.rule) for v in vs] \
        == [("caller.py", "FC-RECOMPILE")]


def test_unhashable_dataclass_across_files(tmp_path):
    (tmp_path / "tiles.py").write_text(textwrap.dedent("""
        import dataclasses

        @dataclasses.dataclass
        class Tile:
            bm: int = 8
    """))
    (tmp_path / "caller.py").write_text(textwrap.dedent("""
        import functools
        import jax
        from tiles import Tile

        @functools.partial(jax.jit, static_argnames=("tile",))
        def run(x, tile):
            return x

        def go(x):
            return run(x, tile=Tile())
    """))
    vs = [v for v in check_paths([tmp_path]) if not v.suppressed]
    assert len(vs) == 1 and vs[0].rule == "FC-RECOMPILE"
    assert "Tile" in vs[0].message


# ---------------------------------------------------------------------------
# the three historical bugs (acceptance criteria): each reintroduction
# must flag with the matching rule ID
# ---------------------------------------------------------------------------


def test_pr1_program_id_in_pl_when_flags():
    src = textwrap.dedent("""
        from jax.experimental import pallas as pl

        def kernel(acc_ref, o_ref):
            @pl.when(pl.program_id(2) == 0)
            def _():
                acc_ref[...] = acc_ref[...] * pl.program_id(2)
    """)
    vs = [v for v in check_source(src) if not v.suppressed]
    assert [v.rule for v in vs] == ["FC-PALLAS"]
    assert vs[0].line == 7          # only the read INSIDE the region


def test_pr4_eager_lr_sync_flags():
    src = textwrap.dedent("""
        class Trainer:
            def train(self, n_steps):
                for i in range(n_steps):
                    lr = float(self.cfg.lr_schedule(i))
                    self.dispatch(i, lr)
    """)
    vs = [v for v in check_source(src) if not v.suppressed]
    assert [v.rule for v in vs] == ["FC-HOSTSYNC"]


def test_pr4_unlocked_pipeline_write_flags():
    src = textwrap.dedent("""
        import threading

        class DataPipeline:
            def __init__(self):
                self._lock = threading.RLock()
                self._mixture = {}

            def set_mixture(self, weights):
                self._mixture = dict(weights)

            def next_batch(self):
                with self._lock:
                    return dict(self._mixture)
    """)
    vs = [v for v in check_source(src) if not v.suppressed]
    assert [v.rule for v in vs] == ["FC-LOCK"]
    assert "set_mixture" in vs[0].message


# ---------------------------------------------------------------------------
# the actual tree stays clean (same contract as the CI flopcheck job)
# ---------------------------------------------------------------------------


def test_tree_has_no_unsuppressed_violations():
    vs = check_paths([ROOT / "src", ROOT / "tests"],
                     exclude=("flopcheck_corpus",))
    active = [v.format() for v in vs if not v.suppressed]
    assert not active, "\n".join(active)


def test_corpus_is_excluded_from_tree_scans():
    files = list(iter_py_files([ROOT / "tests"],
                               exclude=("flopcheck_corpus",)))
    assert files and not [f for f in files if "flopcheck_corpus" in str(f)]
