"""EDiT on heterogeneous workers: 3 clusters with different speeds train a
tiny model with time-based synchronization; one worker goes rogue mid-run
and is eliminated by the pseudo-gradient penalty.

    PYTHONPATH=src python examples/edit_heterogeneous.py
"""
import jax
import jax.numpy as jnp

from repro import api
from repro.configs.base import get_smoke_config
from repro.core.edit import EDiTConfig, EDiTTrainer
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.launch.mesh import make_local_mesh
from repro.optim import adamw

cfg = get_smoke_config("phi3-mini-3.8b")
runner = api.Runner(cfg, make_local_mesh(1, 1), max_seq=64)
step = jax.jit(runner.make_train_step(2))
params = runner.init_params(0)

ROGUE_AFTER = 3

def worker_step(w, opt, batch, i, lr):
    if opt is None:
        opt = adamw.init_opt_state(w)
    jb = {k: jnp.asarray(v) for k, v in batch.items()}
    w, opt, m = step(w, opt, jb, jnp.int32(i), jax.random.PRNGKey(i),
                     jnp.float32(lr))
    return w, opt, m["loss"]

edit = EDiTTrainer(params, worker_step,
                   EDiTConfig(sync_every=3, time_threshold_s=1.0,
                              anomaly_sigma=2.0),
                   num_workers=3, worker_speeds=[1.0, 1.5, 0.7])
pipes = [DataPipeline(PipelineConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                     batch_size=2, seed=s))
         for s in range(3)]
for r in range(6):
    batches = [[p.next_batch() for _ in range(6)] for p in pipes]
    if r >= ROGUE_AFTER:
        # worker 2's "cluster" corrupts its replica (hardware fault model)
        edit.workers[2] = jax.tree.map(lambda x: x * 30.0, edit.workers[2])
    rec = edit.round(batches, lr=1e-3)
    print(f"round {r}: loss={rec['mean_loss']:.3f} kept={rec['kept']} "
          f"weights={rec['weights']}")
