"""Online continuous-batching demo: requests arrive over time, join the
running decode batch, stream tokens, and survive preemption — on a real
(tiny) Ling-style model with a paged device KV cache.

    PYTHONPATH=src python examples/serve_online.py

See docs/serving.md for the engine anatomy and launch/serve.py --online
for the full Poisson load generator.
"""
import numpy as np

from repro import api
from repro.configs.base import get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.serving.online import OnlineConfig, OnlineEngine, OnlineRequest

cfg = get_smoke_config("ling-lite")
runner = api.Runner(cfg, make_local_mesh(1, 1), fsdp=False,
                    seq_parallel=False, max_seq=64)
params = runner.init_params(0)

# a deliberately small page pool so late arrivals preempt the youngest
# resident (watch `preemptions` below) — requests still all complete
eng = OnlineEngine(runner, params,
                   OnlineConfig(max_slots=4, max_context=48, page_size=8,
                                n_pages=8, prefill_chunk=8))

rs = np.random.RandomState(0)
sys_prompt = rs.randint(0, cfg.vocab_size, 16).astype(np.int32)
reqs = [OnlineRequest(rid=i, prompt=sys_prompt, max_new=16)
        for i in range(10)]

# no prefix keys anywhere: the first request's prefill publishes the
# shared system prompt's two full pages into the content-addressed radix
# cache; every later arrival attaches them and skips re-prefilling the
# 16 tokens (watch `prefix_hits` / `radix_hit_tokens` below)
eng.submit(reqs[0])
while reqs[0].state != "decode":
    eng.tick()

for r in reqs[1:4]:
    eng.submit(r)
for _ in range(6):                      # a few ticks of mixed prefill+decode
    eng.tick()
for r in reqs[4:]:                      # late arrivals join the live batch
    eng.submit(r)
eng.run()

for r in reqs:
    assert r.done and len(r.out) == r.max_new
    assert r.out == reqs[0].out         # same prompt, greedy -> same stream
print(f"requests={len(reqs)}  ticks={eng.ticks}  "
      f"preemptions={eng.n_preemptions}  "
      f"compiles=prefill:{eng.prefill_traces}+decode:{eng.decode_traces}")
print(f"allocator: {eng.alloc.stats}")
assert eng.prefill_traces == 1 and eng.decode_traces == 1
assert eng.alloc.stats["prefix_hits"] >= 9   # every follower attached
