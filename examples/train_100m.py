"""End-to-end driver: train a ~100M-param Ling-style MoE for a few hundred
steps with the full engine — sharded donated train step, microbatch grad
accumulation, device-side spike guard with async metric drains, WSD
schedule, XPUTimer tracing, async PCache checkpoints (--resume continues
the newest one).

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--tiny]

NOTE: ~100M params on this 1-CPU container runs at ~5-15 s/step; use
--tiny for a quick functional pass (finishes in ~1 minute).
"""
import argparse
import dataclasses

from repro import api
from repro.configs.base import MoEConfig, ModelConfig
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.launch.mesh import make_local_mesh
from repro.optim.schedule import AccumWarmup, WSDSchedule
from repro.telemetry.xputimer import XPUTimer
from repro.training.trainer import TrainConfig, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--tiny", action="store_true")
ap.add_argument("--accum", type=int, default=1,
                help="microbatches accumulated per optimizer step")
ap.add_argument("--bs-warmup", default=None, metavar="START:END:STEPS",
                help="grow the global batch START->END sequences over "
                     "STEPS steps by scheduling the accum count (§3.4.1); "
                     "START/END must be multiples of the microbatch")
ap.add_argument("--resume", action="store_true",
                help="resume from the newest checkpoint")
ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
args = ap.parse_args()

if args.tiny:
    d, layers, vocab, seq, batch = 128, 2, 2048, 128, 4
    args.steps = min(args.steps, 30)
else:
    d, layers, vocab, seq, batch = 512, 8, 32768, 256, 4

cfg = ModelConfig(
    arch_id="ling-100m", family="moe", source="example",
    n_layers=layers, d_model=d, n_heads=8, n_kv_heads=4, d_ff=4 * d,
    vocab_size=vocab, mlp_act="swiglu", norm_head=True,
    moe=MoEConfig(n_experts=16, top_k=4, expert_d_ff=d,
                  n_shared_experts=1, router_warmup_steps=50))
print(f"params: {cfg.param_count()/1e6:.0f}M total / "
      f"{cfg.active_param_count()/1e6:.0f}M active")

runner = api.Runner(cfg, make_local_mesh(1, 1), max_seq=seq)
pipe = DataPipeline(PipelineConfig(vocab_size=vocab, seq_len=seq,
                                   batch_size=batch))
bs_warmup = None
if args.bs_warmup:
    s, e, n = (int(x) for x in args.bs_warmup.split(":"))
    bs_warmup = AccumWarmup(microbatch=batch, start=s, end=e, warmup_steps=n)
trainer = Trainer(
    runner, pipe,
    TrainConfig(n_steps=args.steps,
                lr_schedule=WSDSchedule(max_lr=6e-4, warmup_steps=30,
                                        total_steps=args.steps),
                accum_steps=args.accum, bs_warmup=bs_warmup,
                checkpoint_dir=args.checkpoint_dir, checkpoint_every=100,
                log_every=10),
    timer=XPUTimer())
if args.resume:
    print(f"resumed from {trainer.restore('latest')} at step {trainer.step}")
hist = trainer.train()
trainer.close()
rep = trainer.timer.diagnose()
if hist:
    print(f"final loss {hist[-1]['loss']:.4f}; spikes skipped: "
          f"{rep['counters'].get('spike_skipped', 0)}; metric drains: "
          f"{trainer.metric_drains} over {len(hist)} steps")
    print(f"dominant span: {rep.get('dominant_span')}")
else:
    print("no steps ran (schedule already complete)")
