"""Quickstart: train a tiny Ling-style fine-grained MoE for 30 steps on the
synthetic corpus, watch the loss fall, then greedy-decode a few tokens.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import api
from repro.configs.base import get_smoke_config
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.optim import adamw

cfg = get_smoke_config("ling-lite")          # 2-layer fine-grained MoE
mesh = make_local_mesh(1, 1)
runner = api.Runner(cfg, mesh, max_seq=128)

params = runner.init_params(seed=0)
opt = adamw.init_opt_state(params)
step = jax.jit(runner.make_train_step(global_batch=4))
pipe = DataPipeline(PipelineConfig(vocab_size=cfg.vocab_size, seq_len=128,
                                   batch_size=4))

print(f"model: {cfg.arch_id} ({cfg.param_count()/1e6:.1f}M params, "
      f"{cfg.active_param_count()/1e6:.1f}M active)")
for i in range(30):
    batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
    params, opt, m = step(params, opt, batch, jnp.int32(i),
                          jax.random.PRNGKey(i), jnp.float32(1e-3))
    if i % 5 == 0:
        print(f"step {i:3d}  loss={float(m['loss']):.4f}  "
              f"balance={float(m['router/balance_loss']):.3f}  "
              f"dropped={float(m['moe/dropped_frac']):.4f}")

# greedy decode a few tokens with the segment-cache-backed decode step
decode, _ = runner.make_decode_step(global_batch=4, seq_len=128)
decode = jax.jit(decode)
caches = M.init_caches(cfg, runner.env, 4, 128)
tok = jnp.zeros((4,), jnp.int32)
out = []
for pos in range(8):
    tok, caches = decode(params, caches, tok, jnp.int32(pos))
    out.append(tok)
print("decoded:", jnp.stack(out, 1))
