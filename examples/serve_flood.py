"""Flood offline inference demo: batched requests with a shared system
prompt (prefix cache), segment growth, and a trained tiny model.

    PYTHONPATH=src python examples/serve_flood.py
"""
import numpy as np

from repro.serving.flood import FloodEngine, GenRequest
from repro.serving.segment_cache import SegmentCache

# scheduler-level demo with a cost-model "model": see launch/serve.py for
# the real-model engine
rs = np.random.RandomState(0)
prompt = rs.randint(0, 1000, 16).astype(np.int32)   # shared system prompt

reqs = [GenRequest(rid=i, prompt=prompt, max_new=32,
                   prefix_key="system-prompt") for i in range(24)]

def embed(rr):
    return {"n": len(rr)}

def head(x, rr):
    return [(r.rid * 7 + len(r.out)) % 1000 for r in rr]

cache = SegmentCache(max_tokens=4096, initial_segment=16, extend_chunk=16)
eng = FloodEngine([lambda x: x] * 4, head, embed, cache=cache, microbatch=4)
eng.submit(reqs[:1])
cache.register_prefix(0, "system-prompt")     # later requests share it
eng.submit(reqs[1:])
stats = eng.run()
print(f"tokens={stats.tokens_out}  utilization={stats.utilization:.1%}")
print(f"cache: {cache.stats}")
assert stats.tokens_out == 24 * 32
